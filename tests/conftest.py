"""Shared pytest config.

x64 is enabled for oracle-grade numerics (model code always passes explicit
dtypes, so this does not change model behaviour).  XLA device-count flags are
deliberately NOT set here — smoke tests and benches must see 1 device; the
multi-pod dry-run sets its own flags in a fresh process (launch/dryrun.py).

``hypothesis`` is an OPTIONAL test dependency (the ``test`` extra in
pyproject.toml).  When absent, a stub module is installed in ``sys.modules``
before collection so that modules doing ``from hypothesis import given, ...``
at import time still collect; every ``@given`` property test then SKIPS at
runtime instead of killing the whole run with a collection error.
"""

import os
import subprocess
import sys
import types

import jax
import pytest

jax.config.update("jax_enable_x64", True)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


# ---------------------------------------------------------------------------
# Optional-dependency shim: hypothesis
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def _given_stub(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg wrapper: the strategy-bound parameters of the original
            # must not be seen by pytest (they are not fixtures).
            def skipper():
                pytest.skip("hypothesis not installed (pip install "
                            "'repro[test]' for property tests)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings_stub(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    _hyp = types.ModuleType("hypothesis")
    _strategies = types.ModuleType("hypothesis.strategies")
    _any = _AnyStrategy()
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite", "data"):
        setattr(_strategies, _name, _any)
    _hyp.given = _given_stub
    _hyp.settings = _settings_stub
    _hyp.strategies = _strategies
    _hyp.HealthCheck = _any
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strategies


def run_in_subprocess(code: str, *, devices: int = 0, timeout: int = 900,
                      env_extra: dict | None = None) -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh interpreter (for XLA flag isolation)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
