"""Shared pytest config.

x64 is enabled for oracle-grade numerics (model code always passes explicit
dtypes, so this does not change model behaviour).  XLA device-count flags are
deliberately NOT set here — smoke tests and benches must see 1 device; the
multi-pod dry-run sets its own flags in a fresh process (launch/dryrun.py).
"""

import os
import subprocess
import sys

import jax
import pytest

jax.config.update("jax_enable_x64", True)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_in_subprocess(code: str, *, devices: int = 0, timeout: int = 900,
                      env_extra: dict | None = None) -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh interpreter (for XLA flag isolation)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
